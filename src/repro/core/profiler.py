"""HLO-based cost extraction — the JAX analogue of the paper's warm-up
benchmarking (Algorithm 1 'initializes ... with system settings and
benchmarks in the first several iterations').

On real hardware MG-WFBP measures per-layer backward times; in this
CPU-only container we extract exact FLOPs / bytes from compiled HLO
*segments* and convert them to times with ``core.cost_model.Hardware``.

Why segments: ``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE
(verified during prototyping), so whole-program numbers undercount layer
loops.  Lowering (embed, one layer, head) separately with production
shardings gives exact per-segment costs; totals recompose analytically.

Also here: the collective-traffic parser used by the roofline analysis —
it walks compiled HLO text, sums operand bytes of every collective op, and
multiplies ops inside `while` loops by their trip count.  It also reads
*lowered* StableHLO (pre-optimization): on CPU the compiled module
upcasts bf16 collectives to f32, so wire-dtype truth — what the
wire-layout benchmark and the bf16/arena byte assertions need — only
exists before compilation.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

#: StableHLO op name -> compiled-HLO kind (the parser's canonical keys).
_STABLEHLO_COLLECTIVES = {
    "all_reduce": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "collective_permute": "collective-permute",
}


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated collective traffic of one compiled module (per device).

    ``concat_ops`` counts ``concatenate`` ops — not a collective, but the
    tell-tale of the copy-based merged-buffer wire layout: the arena
    layout (``core/sync.py`` ``fuse='arena'``) must lower with zero of
    them, and the wire-layout benchmark reports them per fuse mode.  It
    is kept out of ``counts``/``total_bytes`` so roofline collective
    traffic is unchanged.
    """

    counts: dict[str, int]
    bytes_by_kind: dict[str, int]
    concat_ops: int = 0

    @property
    def total_ops(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'bf16[32,4608]{1,0}' (0 for token etc.)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_shapes(line: str) -> list[str]:
    """Shapes produced by an HLO op line (handles tuple results)."""
    # '%name = (f32[2,3]{1,0}, f32[4]{0}) all-reduce(...)' or
    # '%name = f32[2,3]{1,0} all-reduce(...)'
    m = re.search(r"=\s*(\([^)]*\)|\S+)\s+[\w-]+\(", line)
    if not m:
        return []
    res = m.group(1)
    if res.startswith("("):
        return [s for s in res[1:-1].split(", ") if s]
    return [res]


def _tensor_bytes(tensor_type: str) -> int:
    """Bytes of one StableHLO tensor type body like '100x32xbf16' / 'f32'."""
    parts = tensor_type.strip().split("x")
    dtype = parts[-1]
    if dtype not in _DTYPE_BYTES:
        # stablehlo integer spellings: i8/i32/ui8... -> s8/s32/u8
        alias = {"i": "s", "ui": "u"}
        m = re.match(r"(ui|i)(\d+)$", dtype)
        dtype = f"{alias[m.group(1)]}{m.group(2)}" if m else dtype
        if dtype not in _DTYPE_BYTES:
            return 0
    n = 1
    for d in parts[:-1]:
        if not d.isdigit():
            return 0
        n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _parse_stablehlo(text: str) -> CollectiveStats:
    """Collective stats from lowered (StableHLO) module text.

    Ops with regions (all_reduce) put their type signature on the
    region-closing ``}) : (...) -> ...`` line; the first ``->`` after the
    op start is that signature either way, so a forward scan suffices.

    Counts are *static*: StableHLO ``while`` bodies carry no trip-count
    annotation, so a collective inside a scanned body counts once — use
    compiled-HLO text when loop-multiplied totals matter (the dry-run /
    roofline path does), lowered text when wire dtypes matter.
    """
    counts: dict[str, int] = {}
    nbytes: dict[str, int] = {}
    concat_ops = len(re.findall(r"stablehlo\.concatenate", text))
    for m in re.finditer(r'"?stablehlo\.(\w+)"?[(<]', text):
        kind = _STABLEHLO_COLLECTIVES.get(m.group(1))
        if kind is None:
            continue
        tail = text[m.end() : m.end() + 8000]
        tm = re.search(r"->\s*(\([^)]*\)|tensor<[^>]*>)", tail)
        payload = (
            sum(_tensor_bytes(t) for t in re.findall(r"tensor<([^>]*)>", tm.group(1)))
            if tm
            else 0
        )
        counts[kind] = counts.get(kind, 0) + 1
        nbytes[kind] = nbytes.get(kind, 0) + payload
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes, concat_ops=concat_ops)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Count collective ops and payload bytes in compiled HLO text.

    Lowered StableHLO text is detected and parsed too — use that form
    whenever the *wire dtype* matters (compiled CPU modules upcast bf16
    collectives to f32), but note the while-loop multiplication below is
    compiled-HLO-only (StableHLO has no trip-count annotation, so
    loop-body collectives count once there).

    * operand bytes are taken from the op's *result* shapes (for all-reduce
      result==operand; for all-gather the result is the gathered size which
      upper-bounds wire traffic per device; reduce-scatter result is the
      scattered shard — we use max(result, operands)/2-style accounting
      kept deliberately simple: payload = max(result bytes, operand bytes));
    * ops inside `while` loop bodies are multiplied by the loop trip count
      when XLA printed a known trip count comment, else by the scan length
      inferred from the loop induction comparison.
    """
    if "stablehlo." in hlo_text:
        return _parse_stablehlo(hlo_text)

    counts: dict[str, int] = {}
    nbytes: dict[str, int] = {}
    concat_ops = 0

    # Map computation name -> list of (kind, payload)
    comp_ops: dict[str, list[tuple[str, int]]] = {}
    comp_name = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # data-movement tell-tale (compiled HLO and stablehlo spellings)
        if re.search(r"(?:\s|=\s*)concatenate\(|stablehlo\.concatenate", stripped):
            concat_ops += 1
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->", stripped)
        if m and ("{" in stripped or stripped.endswith("{")):
            comp_name = m.group(1)
            comp_ops.setdefault(comp_name, [])
            continue
        for kind in _COLLECTIVES:
            # match 'kind(' or 'kind-start('
            if re.search(rf"\)?\s{kind}(?:-start)?\(", stripped) and "=" in stripped:
                res_shapes = _result_shapes(stripped)
                payload = sum(_shape_bytes(s) for s in res_shapes)
                # all-reduce-done / all-gather-done re-mention the shape; skip
                if re.search(rf"\s{kind}-done\(", stripped):
                    continue
                if comp_name is not None:
                    comp_ops[comp_name].append((kind, payload))
                counts[kind] = counts.get(kind, 0) + 1
                nbytes[kind] = nbytes.get(kind, 0) + payload
                break

    # Account for while-loop trip counts: find while ops and their body
    # computations, then re-add (trip_count - 1) x body collectives.
    for m in re.finditer(r"while\(.*?\)[^\n]*body=%?([\w.\-]+)[^\n]*", hlo_text):
        body = m.group(1)
        line = m.group(0)
        trip = None
        tc = re.search(r"trip_count=(\d+)", line)
        if tc:
            trip = int(tc.group(1))
        if trip is None or body not in comp_ops:
            continue
        for kind, payload in comp_ops[body]:
            counts[kind] = counts.get(kind, 0) + (trip - 1)
            nbytes[kind] = nbytes.get(kind, 0) + payload * (trip - 1)

    return CollectiveStats(counts=counts, bytes_by_kind=nbytes, concat_ops=concat_ops)


@dataclasses.dataclass
class SegmentCost:
    """Exact cost of one lowered program segment (per device)."""

    name: str
    flops: float
    bytes_accessed: float
    collectives: CollectiveStats
    peak_temp_bytes: int = 0


def segment_cost(name: str, compiled) -> SegmentCost:
    """Extract flops / bytes / collectives from one compiled executable."""
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    return SegmentCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collectives(compiled.as_text()),
        peak_temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
    )


def time_segment(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Wall-clock one jitted/compiled segment: discard ``warmup`` calls
    (compilation, caches), keep the min of ``repeats`` timed calls — the
    same latency estimator ``MeasuredComm.time_psums`` uses, so compute-
    and comm-side measured costs are directly comparable.  This is the
    measured counterpart of ``segment_cost``: same segment decomposition,
    seconds instead of flops."""
    import time as _time

    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, _time.perf_counter() - t0)
    return best
