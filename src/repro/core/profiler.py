"""HLO-based cost extraction — the JAX analogue of the paper's warm-up
benchmarking (Algorithm 1 'initializes ... with system settings and
benchmarks in the first several iterations').

On real hardware MG-WFBP measures per-layer backward times; in this
CPU-only container we extract exact FLOPs / bytes from compiled HLO
*segments* and convert them to times with ``core.cost_model.Hardware``.

Why segments: ``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE
(verified during prototyping), so whole-program numbers undercount layer
loops.  Lowering (embed, one layer, head) separately with production
shardings gives exact per-segment costs; totals recompose analytically.

Also here: the collective-traffic parser used by the roofline analysis —
it walks compiled HLO text, sums operand bytes of every collective op, and
multiplies ops inside `while` loops by their trip count.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated collective traffic of one compiled module (per device)."""

    counts: dict[str, int]
    bytes_by_kind: dict[str, int]

    @property
    def total_ops(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'bf16[32,4608]{1,0}' (0 for token etc.)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_shapes(line: str) -> list[str]:
    """Shapes produced by an HLO op line (handles tuple results)."""
    # '%name = (f32[2,3]{1,0}, f32[4]{0}) all-reduce(...)' or
    # '%name = f32[2,3]{1,0} all-reduce(...)'
    m = re.search(r"=\s*(\([^)]*\)|\S+)\s+[\w-]+\(", line)
    if not m:
        return []
    res = m.group(1)
    if res.startswith("("):
        return [s for s in res[1:-1].split(", ") if s]
    return [res]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Count collective ops and payload bytes in compiled HLO text.

    * operand bytes are taken from the op's *result* shapes (for all-reduce
      result==operand; for all-gather the result is the gathered size which
      upper-bounds wire traffic per device; reduce-scatter result is the
      scattered shard — we use max(result, operands)/2-style accounting
      kept deliberately simple: payload = max(result bytes, operand bytes));
    * ops inside `while` loop bodies are multiplied by the loop trip count
      when XLA printed a known trip count comment, else by the scan length
      inferred from the loop induction comparison.
    """
    counts: dict[str, int] = {}
    nbytes: dict[str, int] = {}

    # Map computation name -> list of (kind, payload)
    comp_ops: dict[str, list[tuple[str, int]]] = {}
    comp_name = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->", stripped)
        if m and ("{" in stripped or stripped.endswith("{")):
            comp_name = m.group(1)
            comp_ops.setdefault(comp_name, [])
            continue
        for kind in _COLLECTIVES:
            # match 'kind(' or 'kind-start('
            if re.search(rf"\)?\s{kind}(?:-start)?\(", stripped) and "=" in stripped:
                res_shapes = _result_shapes(stripped)
                payload = sum(_shape_bytes(s) for s in res_shapes)
                # all-reduce-done / all-gather-done re-mention the shape; skip
                if re.search(rf"\s{kind}-done\(", stripped):
                    continue
                if comp_name is not None:
                    comp_ops[comp_name].append((kind, payload))
                counts[kind] = counts.get(kind, 0) + 1
                nbytes[kind] = nbytes.get(kind, 0) + payload
                break

    # Account for while-loop trip counts: find while ops and their body
    # computations, then re-add (trip_count - 1) x body collectives.
    for m in re.finditer(r"while\(.*?\)[^\n]*body=%?([\w.\-]+)[^\n]*", hlo_text):
        body = m.group(1)
        line = m.group(0)
        trip = None
        tc = re.search(r"trip_count=(\d+)", line)
        if tc:
            trip = int(tc.group(1))
        if trip is None or body not in comp_ops:
            continue
        for kind, payload in comp_ops[body]:
            counts[kind] = counts.get(kind, 0) + (trip - 1)
            nbytes[kind] = nbytes.get(kind, 0) + payload * (trip - 1)

    return CollectiveStats(counts=counts, bytes_by_kind=nbytes)


@dataclasses.dataclass
class SegmentCost:
    """Exact cost of one lowered program segment (per device)."""

    name: str
    flops: float
    bytes_accessed: float
    collectives: CollectiveStats
    peak_temp_bytes: int = 0


def segment_cost(name: str, compiled) -> SegmentCost:
    """Extract flops / bytes / collectives from one compiled executable."""
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    return SegmentCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collectives(compiled.as_text()),
        peak_temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
    )
