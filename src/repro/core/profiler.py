"""HLO-based cost extraction — the JAX analogue of the paper's warm-up
benchmarking (Algorithm 1 'initializes ... with system settings and
benchmarks in the first several iterations').

On real hardware MG-WFBP measures per-layer backward times; in this
CPU-only container we extract exact FLOPs / bytes from compiled HLO
*segments* and convert them to times with ``core.cost_model.Hardware``.

Why segments: ``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE
(verified during prototyping), so whole-program numbers undercount layer
loops.  Lowering (embed, one layer, head) separately with production
shardings gives exact per-segment costs; totals recompose analytically.

Also here: the collective-traffic parser used by the roofline analysis —
it walks compiled HLO text, sums operand bytes of every collective op, and
multiplies ops inside `while` loops by their trip count.  It also reads
*lowered* StableHLO (pre-optimization): on CPU the compiled module
upcasts bf16 collectives to f32, so wire-dtype truth — what the
wire-layout benchmark and the bf16/arena byte assertions need — only
exists before compilation.

Trace-first overlap verification (the DAG-step proof obligation)
----------------------------------------------------------------
The JAX CPU profiler emits no named-scope / op-level spans, so overlap
cannot be read off ``jax.profiler`` output here.  Instead the executed
step self-records: :class:`TraceRecorder` plants host-callback markers
whose *data dependencies* pin them to the events they time — a span
begin consumes the group's packed gradient (fires when the gradient is
ready), a span end consumes the all-reduce output (fires at completion).
Recordings serialize to Chrome-trace JSON (``ph: "X"`` complete events,
``pid`` = device), and one parser — :func:`parse_trace_spans` — reads
recorded traces, committed fixtures under ``tests/data/``, and real
``trace.json.gz`` files alike.  :func:`overlap_report` then computes the
measured overlap fraction (comm time hidden under backward / total comm
time) and the structural DAG property: a non-final ``wfbp_group*`` span
starting before the last backward span ends.
"""

from __future__ import annotations

import dataclasses
import gzip
import json
import pathlib
import re
import threading
import time

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

#: StableHLO op name -> compiled-HLO kind (the parser's canonical keys).
_STABLEHLO_COLLECTIVES = {
    "all_reduce": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "collective_permute": "collective-permute",
}


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated collective traffic of one compiled module (per device).

    ``concat_ops`` counts ``concatenate`` ops — not a collective, but the
    tell-tale of the copy-based merged-buffer wire layout: the arena
    layout (``core/sync.py`` ``fuse='arena'``) must lower with zero of
    them, and the wire-layout benchmark reports them per fuse mode.  It
    is kept out of ``counts``/``total_bytes`` so roofline collective
    traffic is unchanged.
    """

    counts: dict[str, int]
    bytes_by_kind: dict[str, int]
    concat_ops: int = 0

    @property
    def total_ops(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'bf16[32,4608]{1,0}' (0 for token etc.)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_shapes(line: str) -> list[str]:
    """Shapes produced by an HLO op line (handles tuple results)."""
    # '%name = (f32[2,3]{1,0}, f32[4]{0}) all-reduce(...)' or
    # '%name = f32[2,3]{1,0} all-reduce(...)'
    m = re.search(r"=\s*(\([^)]*\)|\S+)\s+[\w-]+\(", line)
    if not m:
        return []
    res = m.group(1)
    if res.startswith("("):
        return [s for s in res[1:-1].split(", ") if s]
    return [res]


def _tensor_bytes(tensor_type: str) -> int:
    """Bytes of one StableHLO tensor type body like '100x32xbf16' / 'f32'."""
    parts = tensor_type.strip().split("x")
    dtype = parts[-1]
    if dtype not in _DTYPE_BYTES:
        # stablehlo integer spellings: i8/i32/ui8... -> s8/s32/u8
        alias = {"i": "s", "ui": "u"}
        m = re.match(r"(ui|i)(\d+)$", dtype)
        dtype = f"{alias[m.group(1)]}{m.group(2)}" if m else dtype
        if dtype not in _DTYPE_BYTES:
            return 0
    n = 1
    for d in parts[:-1]:
        if not d.isdigit():
            return 0
        n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _parse_stablehlo(text: str) -> CollectiveStats:
    """Collective stats from lowered (StableHLO) module text.

    Ops with regions (all_reduce) put their type signature on the
    region-closing ``}) : (...) -> ...`` line; the first ``->`` after the
    op start is that signature either way, so a forward scan suffices.

    Counts are *static*: StableHLO ``while`` bodies carry no trip-count
    annotation, so a collective inside a scanned body counts once — use
    compiled-HLO text when loop-multiplied totals matter (the dry-run /
    roofline path does), lowered text when wire dtypes matter.
    """
    counts: dict[str, int] = {}
    nbytes: dict[str, int] = {}
    concat_ops = len(re.findall(r"stablehlo\.concatenate", text))
    for m in re.finditer(r'"?stablehlo\.(\w+)"?[(<]', text):
        kind = _STABLEHLO_COLLECTIVES.get(m.group(1))
        if kind is None:
            continue
        tail = text[m.end() : m.end() + 8000]
        tm = re.search(r"->\s*(\([^)]*\)|tensor<[^>]*>)", tail)
        payload = (
            sum(_tensor_bytes(t) for t in re.findall(r"tensor<([^>]*)>", tm.group(1)))
            if tm
            else 0
        )
        counts[kind] = counts.get(kind, 0) + 1
        nbytes[kind] = nbytes.get(kind, 0) + payload
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes, concat_ops=concat_ops)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Count collective ops and payload bytes in compiled HLO text.

    Lowered StableHLO text is detected and parsed too — use that form
    whenever the *wire dtype* matters (compiled CPU modules upcast bf16
    collectives to f32), but note the while-loop multiplication below is
    compiled-HLO-only (StableHLO has no trip-count annotation, so
    loop-body collectives count once there).

    * operand bytes are taken from the op's *result* shapes (for all-reduce
      result==operand; for all-gather the result is the gathered size which
      upper-bounds wire traffic per device; reduce-scatter result is the
      scattered shard — we use max(result, operands)/2-style accounting
      kept deliberately simple: payload = max(result bytes, operand bytes));
    * ops inside `while` loop bodies are multiplied by the loop trip count
      when XLA printed a known trip count comment, else by the scan length
      inferred from the loop induction comparison.
    """
    if "stablehlo." in hlo_text:
        return _parse_stablehlo(hlo_text)

    counts: dict[str, int] = {}
    nbytes: dict[str, int] = {}
    concat_ops = 0

    # Map computation name -> list of (kind, payload)
    comp_ops: dict[str, list[tuple[str, int]]] = {}
    comp_name = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # data-movement tell-tale (compiled HLO and stablehlo spellings)
        if re.search(r"(?:\s|=\s*)concatenate\(|stablehlo\.concatenate", stripped):
            concat_ops += 1
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->", stripped)
        if m and ("{" in stripped or stripped.endswith("{")):
            comp_name = m.group(1)
            comp_ops.setdefault(comp_name, [])
            continue
        for kind in _COLLECTIVES:
            # match 'kind(' or 'kind-start('
            if re.search(rf"\)?\s{kind}(?:-start)?\(", stripped) and "=" in stripped:
                res_shapes = _result_shapes(stripped)
                payload = sum(_shape_bytes(s) for s in res_shapes)
                # all-reduce-done / all-gather-done re-mention the shape; skip
                if re.search(rf"\s{kind}-done\(", stripped):
                    continue
                if comp_name is not None:
                    comp_ops[comp_name].append((kind, payload))
                counts[kind] = counts.get(kind, 0) + 1
                nbytes[kind] = nbytes.get(kind, 0) + payload
                break

    # Account for while-loop trip counts: find while ops and their body
    # computations, then re-add (trip_count - 1) x body collectives.
    for m in re.finditer(r"while\(.*?\)[^\n]*body=%?([\w.\-]+)[^\n]*", hlo_text):
        body = m.group(1)
        line = m.group(0)
        trip = None
        tc = re.search(r"trip_count=(\d+)", line)
        if tc:
            trip = int(tc.group(1))
        if trip is None or body not in comp_ops:
            continue
        for kind, payload in comp_ops[body]:
            counts[kind] = counts.get(kind, 0) + (trip - 1)
            nbytes[kind] = nbytes.get(kind, 0) + payload * (trip - 1)

    return CollectiveStats(counts=counts, bytes_by_kind=nbytes, concat_ops=concat_ops)


@dataclasses.dataclass
class SegmentCost:
    """Exact cost of one lowered program segment (per device)."""

    name: str
    flops: float
    bytes_accessed: float
    collectives: CollectiveStats
    peak_temp_bytes: int = 0


def segment_cost(name: str, compiled) -> SegmentCost:
    """Extract flops / bytes / collectives from one compiled executable."""
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    return SegmentCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collectives(compiled.as_text()),
        peak_temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
    )


def time_segment(fn, *args, warmup: int = 1, repeats: int = 3, clock=None) -> float:
    """Wall-clock one jitted/compiled segment: discard ``warmup`` calls
    (compilation, caches), keep the min of ``repeats`` timed calls — the
    same latency estimator ``MeasuredComm.time_psums`` uses, so compute-
    and comm-side measured costs are directly comparable.  This is the
    measured counterpart of ``segment_cost``: same segment decomposition,
    seconds instead of flops.  ``clock`` is injectable (FakeClock
    pattern) so tests never sleep or assert on real wall-clock deltas."""
    import jax

    if clock is None:
        clock = time.perf_counter
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = clock()
        jax.block_until_ready(fn(*args))
        best = min(best, clock() - t0)
    return best


# ---------------------------------------------------------------------------
# Self-recorded execution traces (the DAG-step overlap proof)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed scope of one device, in Chrome-trace units (µs)."""

    name: str
    device: int
    start_us: float
    dur_us: float
    args: dict = dataclasses.field(default_factory=dict)

    @property
    def end_us(self) -> float:
        return self.start_us + self.dur_us


#: ``wfbp_group{gi}_l{lo}_{hi}`` — the sync engine's per-group scope name.
GROUP_SPAN_RE = re.compile(r"^wfbp_group(\d+)_l(\d+)_(\d+)$")

#: Backward-compute scopes the DAG step records (``bwd_<event>``).
BWD_SPAN_PREFIX = "bwd_"


class TraceRecorder:
    """Host-callback span recorder for jitted steps.

    The pattern: ``span_begin`` plants a ``jax.debug.callback`` whose
    operand is (a cheap scalar of) the value that *becomes ready* when
    the span starts — the runtime cannot fire the callback before its
    operand exists, so the host timestamp is a true not-before bound.
    ``span_end`` does the same with the value the span *produces*.  The
    pair is matched by name per device.  Timestamps are
    ``time.perf_counter_ns`` on the host (injectable for tests).

    Under ``shard_map`` each device shard fires its own callback; pass
    ``device=jax.lax.axis_index(...)`` so spans attribute per device.
    Appends are lock-guarded — the CPU runtime may fire callbacks from
    several device threads.
    """

    def __init__(self, clock_ns=None):
        self._clock_ns = clock_ns or time.perf_counter_ns
        self._lock = threading.Lock()
        self._events: list[tuple[str, str, int, int, int]] = []  # name, ph, dev, t_ns, nbytes

    # -- recording (called from inside traced code) -------------------------

    def _mark(self, name: str, ph: str, nbytes: int, device) -> None:
        t = int(self._clock_ns())
        with self._lock:
            self._events.append((name, ph, int(device), t, int(nbytes)))

    def span_begin(self, name: str, dep, *, device=0, nbytes: int = 0):
        """Record the start of ``name`` when ``dep`` becomes ready.

        ``dep`` must be (or contain) the value whose readiness defines
        the span start — e.g. the packed gradient arena right before its
        ``psum``.  Returns ``dep`` unchanged for ergonomic chaining."""
        import jax

        jax.debug.callback(
            lambda d, _x: self._mark(name, "B", nbytes, d), device, _cheap_dep(dep)
        )
        return dep

    def span_end(self, name: str, val, *, device=0, nbytes: int = 0):
        """Record the end of ``name`` when ``val`` becomes ready."""
        import jax

        jax.debug.callback(
            lambda d, _x: self._mark(name, "E", nbytes, d), device, _cheap_dep(val)
        )
        return val

    # -- reading back --------------------------------------------------------

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def spans(self) -> list[Span]:
        """Pair B/E markers into spans (per name × device, FIFO order)."""
        with self._lock:
            events = list(self._events)
        open_: dict[tuple[str, int], list[tuple[int, int]]] = {}
        out: list[Span] = []
        for name, ph, dev, t_ns, nbytes in sorted(events, key=lambda e: e[3]):
            key = (name, dev)
            if ph == "B":
                open_.setdefault(key, []).append((t_ns, nbytes))
            else:
                if not open_.get(key):
                    continue  # unmatched end (cleared mid-step)
                t0, b0 = open_[key].pop(0)
                args = {"bytes": max(b0, nbytes)} if (b0 or nbytes) else {}
                out.append(
                    Span(name=name, device=dev, start_us=t0 / 1e3,
                         dur_us=max(0.0, (t_ns - t0) / 1e3), args=args)
                )
        out.sort(key=lambda s: (s.device, s.start_us))
        return out

    def to_chrome_trace(self) -> dict:
        """Chrome-trace dict: one ``ph: "X"`` complete event per span,
        ``pid`` = device — the same shape real ``trace.json`` files use,
        so one parser serves recordings, fixtures, and live profiles."""
        return {
            "displayTimeUnit": "ns",
            "traceEvents": [
                {
                    "name": s.name, "ph": "X", "pid": s.device, "tid": 0,
                    "ts": s.start_us, "dur": s.dur_us, "args": s.args,
                }
                for s in self.spans()
            ],
        }

    def save(self, path) -> None:
        """Write the Chrome trace to ``path`` (gzipped iff it ends .gz)."""
        data = json.dumps(self.to_chrome_trace(), indent=1, sort_keys=True)
        if str(path).endswith(".gz"):
            with gzip.open(path, "wt") as f:
                f.write(data)
        else:
            with open(path, "w") as f:
                f.write(data)


def _cheap_dep(x):
    """A scalar that depends on ``x`` without materializing it host-side —
    callbacks transfer their operands, so ship 1 element, not the arena.
    Pytrees (the variadic wire path) resolve to their first leaf."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree.leaves(x)
    x0 = leaves[0] if leaves else 0.0
    if hasattr(x0, "ravel") and getattr(x0, "ndim", 0) > 0:
        return x0.ravel()[0]
    return jnp.asarray(x0)


def parse_trace_spans(trace) -> list[Span]:
    """Parse Chrome-trace ``X`` events into :class:`Span` rows.

    ``trace`` is a dict, a JSON string, or a path to ``.json`` /
    ``.json.gz`` — recorded traces, committed ``tests/data/`` fixtures,
    and real profiler dumps all funnel through here.  ``B``/``E`` event
    pairs are folded into complete spans; events without a duration are
    skipped.  Devices are taken from ``pid``.
    """
    if isinstance(trace, pathlib.PurePath):
        trace = str(trace)
    if isinstance(trace, (str, bytes)) and not str(trace).lstrip().startswith("{"):
        opener = gzip.open if str(trace).endswith(".gz") else open
        with opener(trace, "rt") as f:
            trace = json.load(f)
    elif isinstance(trace, (str, bytes)):
        trace = json.loads(trace)
    events = trace.get("traceEvents", trace) if isinstance(trace, dict) else trace

    spans: list[Span] = []
    open_: dict[tuple[str, int], list[dict]] = {}
    for ev in events:
        ph = ev.get("ph")
        name = ev.get("name")
        if not name:
            continue
        dev = int(ev.get("pid", 0))
        if ph == "X":
            spans.append(
                Span(name=name, device=dev, start_us=float(ev["ts"]),
                     dur_us=float(ev.get("dur", 0.0)), args=dict(ev.get("args", {})))
            )
        elif ph == "B":
            open_.setdefault((name, dev), []).append(ev)
        elif ph == "E":
            stack = open_.get((name, dev))
            if stack:
                b = stack.pop(0)
                spans.append(
                    Span(name=name, device=dev, start_us=float(b["ts"]),
                         dur_us=float(ev["ts"]) - float(b["ts"]),
                         args=dict(b.get("args", {})))
                )
    spans.sort(key=lambda s: (s.device, s.start_us))
    return spans


def _union_len(intervals: list[tuple[float, float]]) -> float:
    total = 0.0
    end = -float("inf")
    for a, b in sorted(intervals):
        if b <= end:
            continue
        total += b - max(a, end)
        end = b
    return total


def _overlap_with_union(lo: float, hi: float, intervals: list[tuple[float, float]]) -> float:
    """Length of [lo, hi] ∩ (∪ intervals)."""
    clipped = [(max(a, lo), min(b, hi)) for a, b in intervals if b > lo and a < hi]
    return _union_len(clipped)


def overlap_report(spans: list[Span]) -> dict:
    """Measured comm/compute overlap from parsed spans.

    Comm spans are the ``wfbp_group{gi}_l{lo}_{hi}`` scopes; backward
    spans are the ``bwd_*`` scopes the DAG step records.  Per device the
    report intersects each comm span with the backward *window* (first
    backward start .. last backward end) and with the union of the
    backward compute spans themselves; aggregated:

    * ``overlap_fraction`` — Σ comm-time-inside-backward-window / Σ comm
      time: the issue-order property the DAG step buys.  Comm placed in
      this window is what an async fabric hides (the paper's WFBP/MG-WFBP
      ratio); the serialized issue order scores ~0 because every group
      issues after the window closes.
    * ``hidden_fraction`` — the stricter Σ comm-time-intersecting-backward
      *compute spans* / Σ comm time: true wall-clock concurrency.  On a
      serial backend (CPU) this can honestly read 0 even under the DAG
      step — issued comm executes in the gaps between backward segments —
      while a real accelerator overlaps it; use ``overlap_fraction`` for
      backend-robust assertions and this for real-fabric measurement.
    * ``n_overlapped_starts`` — comm spans starting strictly before the
      device's last backward span ends (the structural DAG property: a
      merged all-reduce issued *inside* backward);
    * ``groups`` — per-group rows from device 0 (name, layers, bytes,
      start/dur, window/hidden time, the starts-before flag) for tables.

    Returns zeros (not an error) when no comm spans parse — callers
    assert on the fields, so an empty trace fails loudly there.
    """
    by_dev: dict[int, dict[str, list[Span]]] = {}
    for s in spans:
        d = by_dev.setdefault(s.device, {"comm": [], "bwd": []})
        if GROUP_SPAN_RE.match(s.name):
            d["comm"].append(s)
        elif s.name.startswith(BWD_SPAN_PREFIX):
            d["bwd"].append(s)

    total_comm = hidden = windowed = 0.0
    n_overlapped_starts = 0
    n_comm_spans = 0
    groups_out: list[dict] = []
    first_dev = min(by_dev) if by_dev else None
    for dev in sorted(by_dev):
        comm, bwd = by_dev[dev]["comm"], by_dev[dev]["bwd"]
        bwd_iv = [(s.start_us, s.end_us) for s in bwd]
        first_bwd_start = min((s.start_us for s in bwd), default=0.0)
        last_bwd_end = max((s.end_us for s in bwd), default=0.0)
        window = [(first_bwd_start, last_bwd_end)] if bwd else []
        for s in comm:
            h = _overlap_with_union(s.start_us, s.end_us, bwd_iv)
            w = _overlap_with_union(s.start_us, s.end_us, window)
            starts_inside = bool(bwd) and s.start_us < last_bwd_end
            total_comm += s.dur_us
            hidden += h
            windowed += w
            n_comm_spans += 1
            if starts_inside:
                n_overlapped_starts += 1
            if dev == first_dev:
                m = GROUP_SPAN_RE.match(s.name)
                groups_out.append(
                    {
                        "name": s.name,
                        "group": int(m.group(1)),
                        "layers": [int(m.group(2)), int(m.group(3))],
                        "bytes": int(s.args.get("bytes", 0)),
                        "start_us": s.start_us,
                        "dur_us": s.dur_us,
                        "window_us": w,
                        "hidden_us": h,
                        "starts_before_bwd_end": starts_inside,
                    }
                )
    groups_out.sort(key=lambda g: g["group"])
    return {
        "n_devices": len(by_dev),
        "n_comm_spans": n_comm_spans,
        "n_bwd_spans": sum(len(d["bwd"]) for d in by_dev.values()),
        "total_comm_us": total_comm,
        "windowed_comm_us": windowed,
        "hidden_comm_us": hidden,
        "overlap_fraction": (windowed / total_comm) if total_comm > 0 else 0.0,
        "hidden_fraction": (hidden / total_comm) if total_comm > 0 else 0.0,
        "n_overlapped_starts": n_overlapped_starts,
        "groups": groups_out,
    }
