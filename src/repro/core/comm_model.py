"""α–β communication cost models for all-reduce (paper §II-D, Table II).

The paper models a single all-reduce of ``M`` bytes across ``N`` workers as

    T_ar(M) = a + b * M                                           (Eq. 9)

where ``a`` (startup, seconds) and ``b`` (seconds/byte) depend on the
all-reduce algorithm and the point-to-point parameters:

    alpha : p2p latency between two nodes (s)
    beta  : p2p transmission time per byte (s/B)
    gamma : reduction (summation) time per byte on one node (s/B)

Table II of the paper gives (a, b) for four classic algorithms; all are
implemented below.  The key property exploited by MG-WFBP is Eq. 10:

    T_ar(M1) + T_ar(M2) > T_ar(M1 + M2)        (because a > 0)

so merging messages strictly reduces pure communication time.

TPU adaptation
--------------
On a TPU v5e pod the DP all-reduce runs over ICI (2-D torus, ~50 GB/s per
link per direction, ~1 µs per-hop latency) instead of 10GbE MPI.  The form
of the model is unchanged; only the constants move.  ``TpuInterconnect``
builds effective (a, b) for a psum over one or more mesh axes, including a
hierarchical two-level model for cross-pod (DCN) reduction:

    in-pod reduce-scatter  ->  cross-pod all-reduce  ->  in-pod all-gather

which composes as a + b affinely, so the downstream schedule math (which
only needs ``a`` and ``b``) is untouched.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Classic MPI-style models (paper Table II)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AllReduceModel:
    """Affine all-reduce cost model ``T_ar(M) = a + b*M`` (Eq. 9)."""

    a: float  # startup, seconds
    b: float  # seconds per byte
    name: str = "affine"

    def __call__(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def merged_gain(self, m1: float, m2: float) -> float:
        """T(m1) + T(m2) - T(m1+m2) = a  (Eq. 10); >0 whenever a > 0."""
        return self(m1) + self(m2) - self(m1 + m2)


def binary_tree(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Binary-tree all-reduce (Table II row 1)."""
    lg = math.log2(n)
    return AllReduceModel(a=2 * alpha * lg, b=(2 * beta + gamma) * lg, name="binary_tree")


def recursive_doubling(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Recursive-doubling all-reduce (Table II row 2)."""
    lg = math.log2(n)
    return AllReduceModel(a=alpha * lg, b=(beta + gamma) * lg, name="recursive_doubling")


def recursive_halving_doubling(
    n: int, alpha: float, beta: float, gamma: float
) -> AllReduceModel:
    """Recursive halving-and-doubling (Rabenseifner; Table II row 3)."""
    lg = math.log2(n)
    return AllReduceModel(
        a=2 * alpha * lg,
        b=2 * beta - (2 * beta + gamma) / n + gamma,
        name="recursive_halving_doubling",
    )


def ring(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Ring all-reduce (Table II row 4) — the NCCL/ICI workhorse."""
    return AllReduceModel(
        a=2 * (n - 1) * alpha,
        b=2 * (n - 1) / n * beta + (n - 1) / n * gamma,
        name="ring",
    )


ALGORITHMS: dict[str, Callable[[int, float, float, float], AllReduceModel]] = {
    "binary_tree": binary_tree,
    "recursive_doubling": recursive_doubling,
    "recursive_halving_doubling": recursive_halving_doubling,
    "ring": ring,
}


def fit_affine(
    nbytes: Sequence[float], seconds: Sequence[float], name: str = "measured"
) -> AllReduceModel:
    """Least-squares (a, b) from measured (M, T_ar(M)) pairs.

    This is the fit of the journal version's Fig. 5(b): time real
    all-reduces over a size sweep, regress T = a + b·M.  Negative
    intercepts/slopes (possible on noisy tiny sweeps where the size range
    does not resolve the startup term) are clamped to zero — the
    schedule math requires a, b ≥ 0 (Eq. 10's merge gain IS ``a``).
    """
    x = np.asarray(nbytes, dtype=float)
    y = np.asarray(seconds, dtype=float)
    if x.shape != y.shape or x.size < 2:
        raise ValueError(f"need ≥2 (size, time) pairs, got {x.size}")
    coeffs, *_ = np.linalg.lstsq(np.stack([np.ones_like(x), x], axis=1), y, rcond=None)
    a, b = float(coeffs[0]), float(coeffs[1])
    return AllReduceModel(a=max(a, 0.0), b=max(b, 0.0), name=name)


# ---------------------------------------------------------------------------
# Paper's measured environment: 8-node K80 cluster, 10GbE + OpenMPI
# ---------------------------------------------------------------------------

#: Paper §V-A: measured ring-all-reduce startup 2(N-1)·alpha was
#: 90.52 / 271.56 / 633.64 µs for N = 2 / 4 / 8  =>  alpha ≈ 45 µs.
PAPER_10GBE_ALPHA = 45.26e-6
#: 10GbE effective payload bandwidth ≈ 1.07 GB/s (paper: 200KB x8 in ~1.5ms
#: includes startup; slope fit from Fig. 5(b) gives roughly 1/1.07e9 s/B).
PAPER_10GBE_BETA = 1.0 / 1.07e9
#: Summation of two fp32 numbers: K80-era CPU/GPU reduce ≈ 30 GB/s.
PAPER_GAMMA = 1.0 / 30e9


def paper_cluster_model(n: int, algorithm: str = "ring") -> AllReduceModel:
    """(a, b) for the paper's 10GbE cluster at ``n`` nodes."""
    return ALGORITHMS[algorithm](n, PAPER_10GBE_ALPHA, PAPER_10GBE_BETA, PAPER_GAMMA)


# ---------------------------------------------------------------------------
# TPU v5e interconnect model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuInterconnect:
    """Effective α–β parameters for collectives on a TPU v5e mesh.

    ici_link_bw   : per-link, per-direction ICI bandwidth (B/s)
    ici_links     : parallel ICI links usable by one ring direction on the
                    reduced axis (2-D torus: a ring embedded along one axis
                    has 1 link each way; using both directions doubles it,
                    which the ring model's 2(N-1)/N factor already assumes
                    bidirectional use, so we keep ici_links = 1 per ring and
                    expose n_rings for multi-ring decompositions).
    ici_alpha     : per-hop ICI latency (s)
    dcn_bw        : cross-pod (data-center network) bandwidth per pod (B/s)
    dcn_alpha     : cross-pod startup (s)
    fixed_overhead: per-collective software overhead (dispatch, fusion
                    barrier) independent of topology (s)
    """

    ici_link_bw: float = 50e9  # 50 GB/s/link  (brief's constant)
    ici_alpha: float = 1e-6
    n_rings: int = 1
    dcn_bw: float = 25e9
    dcn_alpha: float = 50e-6
    fixed_overhead: float = 5e-6
    # gamma: on-chip reduce is VPU-bound but effectively free vs the wire;
    # modeled at HBM speed.
    gamma: float = 1.0 / 819e9

    def ring_axis(self, n: int) -> AllReduceModel:
        """Ring all-reduce over one ICI mesh axis of size ``n``."""
        if n <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        beta = 1.0 / (self.ici_link_bw * self.n_rings)
        m = ring(n, self.ici_alpha, beta, self.gamma)
        return AllReduceModel(a=m.a + self.fixed_overhead, b=m.b, name="ici_ring")

    def dcn_allreduce(self, n_pods: int) -> AllReduceModel:
        """Ring all-reduce across ``n_pods`` pods over DCN."""
        if n_pods <= 1:
            return AllReduceModel(a=0.0, b=0.0, name="noop")
        m = ring(n_pods, self.dcn_alpha, 1.0 / self.dcn_bw, self.gamma)
        return AllReduceModel(a=m.a + self.fixed_overhead, b=m.b, name="dcn_ring")

    def psum_model(self, axis_sizes: dict[str, int]) -> AllReduceModel:
        """Effective (a, b) for a psum over the given mesh axes.

        Multi-axis reduction is modeled as a sequence of per-axis ring
        all-reduces; message volume per later stage shrinks by the earlier
        axis size when using reduce-scatter composition, which the standard
        multi-ring decomposition achieves.  We model it hierarchically:

          * all ICI axes composed as rings on (almost) the full message
            (2(N-1)/N ≈ 2 regardless of stage split — volume-optimal), with
            startups added per axis;
          * DCN ('pod') stage sees ``1/ici_size`` of the message (it runs on
            reduce-scattered shards — each host only ships its shard).
        """
        a_total, b_total = 0.0, 0.0
        ici_size = 1
        for name, n in axis_sizes.items():
            if name == "pod" or n <= 1:
                continue
            m = self.ring_axis(n)
            a_total += m.a
            # composed rings: stage i operates on 1/prod(previous sizes)
            b_total += m.b / ici_size
            ici_size *= n
        n_pods = axis_sizes.get("pod", 1)
        if n_pods > 1:
            m = self.dcn_allreduce(n_pods)
            a_total += m.a
            b_total += m.b / ici_size
        return AllReduceModel(a=a_total, b=b_total, name="tpu_psum")


#: Default interconnect for the production mesh in launch/mesh.py.
TPU_V5E = TpuInterconnect()


def tpu_psum_model(axis_sizes: dict[str, int]) -> AllReduceModel:
    """Convenience wrapper: TPU_V5E effective model for ``axis_sizes``."""
    return TPU_V5E.psum_model(axis_sizes)
