"""α–β communication cost models for all-reduce (paper §II-D, Table II).

The paper models a single all-reduce of ``M`` bytes across ``N`` workers as

    T_ar(M) = a + b * M                                           (Eq. 9)

where ``a`` (startup, seconds) and ``b`` (seconds/byte) depend on the
all-reduce algorithm and the point-to-point parameters:

    alpha : p2p latency between two nodes (s)
    beta  : p2p transmission time per byte (s/B)
    gamma : reduction (summation) time per byte on one node (s/B)

Table II of the paper gives (a, b) for four classic algorithms; all are
implemented below.  The key property exploited by MG-WFBP is Eq. 10:

    T_ar(M1) + T_ar(M2) > T_ar(M1 + M2)        (because a > 0)

so merging messages strictly reduces pure communication time.

Backend presets
---------------
On a TPU v5e pod the DP all-reduce runs over ICI (2-D torus) instead of
10GbE MPI; on a GPU cluster over NVLink + IB.  The form of the model is
unchanged; only the constants move.  Backend presets live in the fabric
registry (``repro.fabric``): ``get_fabric("tpu_v5e")`` etc. serve per-op
affine models (all-reduce, reduce-scatter, all-gather, all-to-all) from
the same (α, β, γ) primitives.  The historical TPU names —
``TpuInterconnect``, ``TPU_V5E``, ``tpu_psum_model`` — remain importable
from this module as re-exports of the ``tpu_v5e`` preset (lazy, to keep
the primitive layer free of the fabric package).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Classic MPI-style models (paper Table II)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AllReduceModel:
    """Affine all-reduce cost model ``T_ar(M) = a + b*M`` (Eq. 9)."""

    a: float  # startup, seconds
    b: float  # seconds per byte
    name: str = "affine"

    def __call__(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.a + self.b * float(nbytes)

    def merged_gain(self, m1: float, m2: float) -> float:
        """T(m1) + T(m2) - T(m1+m2) = a  (Eq. 10); >0 whenever a > 0."""
        return self(m1) + self(m2) - self(m1 + m2)


def binary_tree(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Binary-tree all-reduce (Table II row 1)."""
    lg = math.log2(n)
    return AllReduceModel(a=2 * alpha * lg, b=(2 * beta + gamma) * lg, name="binary_tree")


def recursive_doubling(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Recursive-doubling all-reduce (Table II row 2)."""
    lg = math.log2(n)
    return AllReduceModel(a=alpha * lg, b=(beta + gamma) * lg, name="recursive_doubling")


def recursive_halving_doubling(
    n: int, alpha: float, beta: float, gamma: float
) -> AllReduceModel:
    """Recursive halving-and-doubling (Rabenseifner; Table II row 3)."""
    lg = math.log2(n)
    return AllReduceModel(
        a=2 * alpha * lg,
        b=2 * beta - (2 * beta + gamma) / n + gamma,
        name="recursive_halving_doubling",
    )


def ring(n: int, alpha: float, beta: float, gamma: float) -> AllReduceModel:
    """Ring all-reduce (Table II row 4) — the NCCL/ICI workhorse."""
    return AllReduceModel(
        a=2 * (n - 1) * alpha,
        b=2 * (n - 1) / n * beta + (n - 1) / n * gamma,
        name="ring",
    )


ALGORITHMS: dict[str, Callable[[int, float, float, float], AllReduceModel]] = {
    "binary_tree": binary_tree,
    "recursive_doubling": recursive_doubling,
    "recursive_halving_doubling": recursive_halving_doubling,
    "ring": ring,
}


def fit_affine(
    nbytes: Sequence[float], seconds: Sequence[float], name: str = "measured"
) -> AllReduceModel:
    """Least-squares (a, b) from measured (M, T_ar(M)) pairs.

    This is the fit of the journal version's Fig. 5(b): time real
    all-reduces over a size sweep, regress T = a + b·M.  Negative
    intercepts/slopes (possible on noisy tiny sweeps where the size range
    does not resolve the startup term) are clamped to zero — the
    schedule math requires a, b ≥ 0 (Eq. 10's merge gain IS ``a``).
    """
    x = np.asarray(nbytes, dtype=float)
    y = np.asarray(seconds, dtype=float)
    if x.shape != y.shape or x.size < 2:
        raise ValueError(f"need ≥2 (size, time) pairs, got {x.size}")
    coeffs, *_ = np.linalg.lstsq(np.stack([np.ones_like(x), x], axis=1), y, rcond=None)
    a, b = float(coeffs[0]), float(coeffs[1])
    return AllReduceModel(a=max(a, 0.0), b=max(b, 0.0), name=name)


# ---------------------------------------------------------------------------
# Paper's measured environment: 8-node K80 cluster, 10GbE + OpenMPI
# ---------------------------------------------------------------------------

#: Paper §V-A: measured ring-all-reduce startup 2(N-1)·alpha was
#: 90.52 / 271.56 / 633.64 µs for N = 2 / 4 / 8  =>  alpha ≈ 45 µs.
PAPER_10GBE_ALPHA = 45.26e-6
#: 10GbE effective payload bandwidth ≈ 1.07 GB/s (paper: 200KB x8 in ~1.5ms
#: includes startup; slope fit from Fig. 5(b) gives roughly 1/1.07e9 s/B).
PAPER_10GBE_BETA = 1.0 / 1.07e9
#: Summation of two fp32 numbers: K80-era CPU/GPU reduce ≈ 30 GB/s.
PAPER_GAMMA = 1.0 / 30e9


def paper_cluster_model(n: int, algorithm: str = "ring") -> AllReduceModel:
    """(a, b) for the paper's 10GbE cluster at ``n`` nodes."""
    return ALGORITHMS[algorithm](n, PAPER_10GBE_ALPHA, PAPER_10GBE_BETA, PAPER_GAMMA)


# ---------------------------------------------------------------------------
# TPU v5e interconnect model — absorbed by the fabric registry
# ---------------------------------------------------------------------------

#: Names now owned by ``repro.fabric`` (the ``tpu_v5e`` preset), re-exported
#: here for back compatibility.  Lazy (PEP 562) because the fabric package
#: imports this module's primitives — an eager import would be circular.
_FABRIC_SHIMS = ("TpuInterconnect", "TPU_V5E", "tpu_psum_model")


def __getattr__(name: str):
    if name in _FABRIC_SHIMS:
        from ..fabric import presets as _presets

        value = getattr(_presets, name)
        globals()[name] = value  # cache: subsequent lookups skip __getattr__
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_FABRIC_SHIMS))
