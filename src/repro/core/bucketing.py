"""Mapping between model parameter pytrees and schedule buckets.

The scheduler (``core.schedule``) works on the paper's flat layer list
``1..L``.  Real models are pytrees.  This module defines the bridge:

  * a ``ParamLayout`` names every *communication unit* in
    backward-availability order, with its gradient message size — the
    ``p`` vector of the paper.  Two unit kinds exist:

      - ``leaf``    — the unit owns whole pytree leaves (its ``paths``);
      - ``stacked`` — the unit is one index of a scan-stacked subtree:
        ``paths`` name the stacked leaves and ``stack_index`` selects the
        slice along their leading axis.  Contiguous stacked units in one
        schedule group collapse into a single ``[a:b]`` slice on the wire.

  * ``bucket_assignment`` groups the units according to a ``Schedule`` so
    the sync engine can issue exactly one all-reduce per group;
  * ``wire_entries`` flattens those groups into the per-group wire plan
    (leaf entries + contiguous ``[a:b)`` scan-slice entries) and
    ``group_arenas`` lays each group out as a flat **arena** — exact
    element offset/size per unit, zero padding, so the arena wire buffer
    is byte-identical in size to a concatenation of the group while
    letting ``fuse='arena'`` pack/unpack in place (kernels/comm_pack);
  * stacked-layer models re-bucket by slicing the leading axis, which is
    also how checkpoints are converted when the schedule changes between
    runs (elastic restarts — a different N gives a different α–β model,
    hence a different optimal 𝕄).

Paths are stored as plain ``str``/``int`` tuples (jax key objects are
normalized away) so a ``ParamLayout`` serializes losslessly into the
``planning.Plan`` JSON artifact.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from .cost_model import LayerCost
from .schedule import Schedule

LEAF = "leaf"
STACKED = "stacked"


def tree_get(tree: Any, path: tuple[Any, ...]) -> Any:
    """Indexed lookup on nested dict/list pytrees (jax key objects ok)."""
    for p in path:
        if hasattr(p, "key"):
            tree = tree[p.key]
        elif hasattr(p, "idx"):
            tree = tree[p.idx]
        else:
            tree = tree[p]
    return tree


def tree_set(tree: Any, path: tuple[Any, ...], value: Any) -> Any:
    """Functional set on nested dict/list pytrees."""
    if not path:
        return value
    p = path[0]
    key = p.key if hasattr(p, "key") else p.idx if hasattr(p, "idx") else p
    if isinstance(tree, dict):
        new = dict(tree)
        new[key] = tree_set(tree[key], path[1:], value)
        return new
    if isinstance(tree, (list, tuple)):
        new_l = list(tree)
        new_l[key] = tree_set(tree[key], path[1:], value)
        return type(tree)(new_l)
    raise TypeError(f"unsupported container {type(tree)} at {path}")


def normalize_path(path: tuple[Any, ...]) -> tuple[Any, ...]:
    """jax key-path entries -> plain str/int keys (JSON-serializable)."""
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "idx"):
            out.append(p.idx)
        elif hasattr(p, "name"):
            out.append(p.name)
        else:
            out.append(p)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class CommUnit:
    """One schedulable gradient message (paper: one 'layer' l with p^(l))."""

    name: str
    index: int  # 1-based position in backward-forward layer order
    grad_bytes: int
    params: int
    # paths into the gradient pytree whose leaves belong to this unit
    # (kind == 'stacked': the stacked leaves, sliced at stack_index)
    paths: tuple[tuple[Any, ...], ...]
    kind: str = LEAF
    stack_index: int = -1


@dataclasses.dataclass(frozen=True)
class ParamLayout:
    """Ordered communication units for a model's gradient pytree.

    ``units[0]`` is layer 1 in the paper's numbering — the *first* forward
    layer, whose gradient lands *last* during backward.
    """

    units: tuple[CommUnit, ...]

    @property
    def num_layers(self) -> int:
        return len(self.units)

    def group_arenas(
        self, schedule: Schedule, shapes: Any, comm_dtype: Any = "float32"
    ) -> "list[GroupArena]":
        """Per-group flat wire layout for ``fuse='arena'`` (see
        ``group_arenas`` below for the shape-source contract)."""
        return group_arenas(self, schedule, shapes, comm_dtype)

    def layer_costs(
        self,
        tokens_per_chip: int,
        hw,
        bwd_flops_fn: Callable[[CommUnit], float] | None = None,
        fwd_flops_fn: Callable[[CommUnit], float] | None = None,
    ) -> list[LayerCost]:
        """LayerCost list in paper order, with pluggable flops models."""
        out = []
        for u in self.units:
            bwd = bwd_flops_fn(u) if bwd_flops_fn else 4.0 * u.params * tokens_per_chip
            fwd = fwd_flops_fn(u) if fwd_flops_fn else 2.0 * u.params * tokens_per_chip
            out.append(
                LayerCost(
                    name=u.name,
                    params=u.params,
                    grad_bytes=u.grad_bytes,
                    bwd_flops=bwd,
                    fwd_flops=fwd,
                )
            )
        return out


def _subtree_paths(tree: Any, prefix: tuple[Any, ...]) -> list[tuple[tuple[Any, ...], Any]]:
    """(full normalized path, leaf) pairs for every leaf under ``tree``."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(prefix + normalize_path(tuple(p)), leaf) for p, leaf in flat]


def _leaf_size(leaf: Any) -> int:
    shape = getattr(leaf, "shape", ())
    return int(np.prod(shape)) if shape else 1


def layout_from_params(
    params: Any,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
    order_key: Callable[[str], float] | None = None,
) -> ParamLayout:
    """Build a per-leaf ParamLayout from a parameter pytree.

    Leaves are ordered by ``order_key`` over their dot-joined path name
    (default: pytree order).  ``model_shards`` divides the DP message size
    (FSDP/TP/EP shrink the data-parallel all-reduce payload).
    """
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    named = []
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path).strip("[].'\"").replace("']['", ".")
        named.append((name, normalize_path(tuple(path)), leaf))
    if order_key is not None:
        named.sort(key=lambda t: order_key(t[0]))
    units = []
    for i, (name, path, leaf) in enumerate(named):
        size = _leaf_size(leaf)
        units.append(
            CommUnit(
                name=name,
                index=i + 1,
                grad_bytes=max(1, size * comm_dtype_bytes // model_shards),
                params=size,
                paths=(path,),
            )
        )
    return ParamLayout(units=tuple(units))


def stacked_lm_layout(
    param_shapes: Any,
    n_stages: int,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
) -> ParamLayout:
    """ParamLayout for the stacked-scan LM param pytree.

    ``param_shapes`` is the model's parameter (shape) pytree with top-level
    subtrees ``embed``, ``stages`` (leaves stacked on a leading axis of
    length ``n_stages``), ``final_norm``, optional ``tail`` and optional
    ``head`` (absent when embeddings are tied).

    Units in paper order (gradient of unit 1 lands last):
      unit 1             = embed                       (leaf kind)
      units 2..n+1       = scan stages                 (stacked kind)
      unit n+2 (if tail) = tail stage                  (leaf kind)
      last unit          = head + final_norm           (leaf kind)
    """

    def leaf_unit(name: str, idx: int, pairs: list[tuple[tuple[Any, ...], Any]]) -> CommUnit:
        size = sum(_leaf_size(leaf) for _, leaf in pairs)
        return CommUnit(
            name=name,
            index=idx,
            grad_bytes=max(1, size * comm_dtype_bytes // model_shards),
            params=size,
            paths=tuple(p for p, _ in pairs),
        )

    units = [leaf_unit("embed", 1, _subtree_paths(param_shapes["embed"], ("embed",)))]

    stage_pairs = _subtree_paths(param_shapes["stages"], ("stages",))
    stage_params = sum(_leaf_size(leaf) for _, leaf in stage_pairs) // n_stages
    stage_paths = tuple(p for p, _ in stage_pairs)
    for i in range(n_stages):
        units.append(
            CommUnit(
                name=f"stage_{i}",
                index=i + 2,
                grad_bytes=max(1, stage_params * comm_dtype_bytes // model_shards),
                params=stage_params,
                paths=stage_paths,
                kind=STACKED,
                stack_index=i,
            )
        )

    idx = n_stages + 2
    if "tail" in param_shapes:
        units.append(leaf_unit("tail", idx, _subtree_paths(param_shapes["tail"], ("tail",))))
        idx += 1

    head_pairs = _subtree_paths(param_shapes["final_norm"], ("final_norm",))
    if "head" in param_shapes:
        head_pairs += _subtree_paths(param_shapes["head"], ("head",))
    units.append(leaf_unit("head", idx, head_pairs))
    return ParamLayout(units=tuple(units))


def layout_for_stacked_lm(
    num_layers: int,
    embed_params: int,
    layer_params: int,
    head_params: int,
    comm_dtype_bytes: int = 4,
    model_shards: int = 1,
) -> ParamLayout:
    """Synthetic ParamLayout for a stacked-scan LM: [embed, layer×L, head].

    Cost-model-only variant (no real pytree behind it); see
    ``stacked_lm_layout`` for the executable one.
    """

    def unit(name: str, idx: int, p: int) -> CommUnit:
        return CommUnit(
            name=name,
            index=idx,
            grad_bytes=max(1, p * comm_dtype_bytes // model_shards),
            params=p,
            paths=((name,),),
        )

    units = [unit("embed", 1, embed_params)]
    units += [unit(f"layer_{i}", i + 2, layer_params) for i in range(num_layers)]
    units += [unit("head", num_layers + 2, head_params)]
    return ParamLayout(units=tuple(units))


def bucket_assignment(layout: ParamLayout, schedule: Schedule) -> list[list[CommUnit]]:
    """Units grouped per schedule group, ascending (layer-1 group first)."""
    if schedule.num_layers != layout.num_layers:
        raise ValueError(
            f"schedule covers {schedule.num_layers} layers, layout has {layout.num_layers}"
        )
    groups = []
    for lo, hi in schedule.groups:
        groups.append([layout.units[i - 1] for i in range(lo, hi + 1)])
    return groups


# One wire entry: ('leaf', path, None) or ('slice', path, (a, b)).
WireEntry = tuple[str, tuple[Any, ...], tuple[int, int] | None]


def wire_entries(layout: ParamLayout, schedule: Schedule) -> list[list[WireEntry]]:
    """Per-group wire plan in backward issue order (layer-L group first).

    Leaf units contribute one entry per leaf path; contiguous stacked
    units collapse into one ``[a:b)`` slice entry per stacked leaf path.
    """
    groups: list[list[WireEntry]] = []
    for units in reversed(bucket_assignment(layout, schedule)):
        entries: list[WireEntry] = []
        runs: dict[tuple, list[int]] = {}
        for u in units:
            if u.kind == LEAF:
                entries.extend(("leaf", p, None) for p in u.paths)
            else:
                runs.setdefault(u.paths, []).append(u.stack_index)
        for paths, idxs in runs.items():
            a, b = min(idxs), max(idxs) + 1
            if sorted(idxs) != list(range(a, b)):
                raise ValueError(f"stacked units in one group must be contiguous: {idxs}")
            entries.extend(("slice", p, (a, b)) for p in paths)
        groups.append(entries)
    return groups


@dataclasses.dataclass(frozen=True)
class ArenaSlot:
    """One wire entry's span inside its group's flat arena."""

    kind: str  # 'leaf' | 'slice'
    path: tuple[Any, ...]
    stack_range: tuple[int, int] | None  # [a, b) over the scan axis
    offset: int  # element offset into the arena
    size: int  # elements
    shape: tuple[int, ...]  # shape of the packed value


@dataclasses.dataclass(frozen=True)
class GroupArena:
    """Flat wire layout of one schedule group.

    Offsets are exact-packed (no per-slot padding): ``size`` equals the
    sum of slot sizes, so the arena's psum payload is byte-identical to
    the concat layout's — the arena only removes copies, never adds wire
    traffic.
    """

    slots: tuple[ArenaSlot, ...]
    size: int  # total elements
    comm_dtype: str

    @property
    def nbytes(self) -> int:
        return self.size * np.dtype(self.comm_dtype).itemsize


def group_arenas(
    layout: ParamLayout,
    schedule: Schedule,
    shapes: Any,
    comm_dtype: Any = "float32",
) -> list[GroupArena]:
    """Plan-time arena layouts, one per schedule group (backward order).

    ``shapes`` is either the parameter (shape) pytree or a callable
    ``path -> shape`` — only leaf shapes are consulted, so abstract
    ``ShapeDtypeStruct`` trees and live gradient trees both work.
    """
    if callable(shapes):
        shape_of = shapes
    else:
        def shape_of(p):
            leaf = tree_get(shapes, p)
            shape = getattr(leaf, "shape", None)
            if shape is None:
                raise TypeError(
                    f"leaf at {p} has no .shape ({type(leaf).__name__}); pass "
                    "arrays / ShapeDtypeStructs or a path->shape callable"
                )
            return tuple(shape)
    dtype_name = np.dtype(comm_dtype).name if not isinstance(comm_dtype, str) else comm_dtype
    arenas = []
    for entries in wire_entries(layout, schedule):
        slots, off = [], 0
        for kind, path, ab in entries:
            shape = tuple(shape_of(path))
            if kind == "slice":
                shape = (ab[1] - ab[0],) + shape[1:]
            n = int(np.prod(shape)) if shape else 1
            slots.append(
                ArenaSlot(
                    kind=kind, path=path, stack_range=ab,
                    offset=off, size=n, shape=shape,
                )
            )
            off += n
        arenas.append(GroupArena(slots=tuple(slots), size=off, comm_dtype=dtype_name))
    return arenas


def layer_buckets_for_scan(schedule: Schedule, num_scan_layers: int) -> tuple[tuple[int, int], ...]:
    """Translate a [embed, L layers, head] schedule into scan segments.

    Returns (start, stop) ranges over the stacked layer axis.  The embed and
    head units are handled separately by the sync engine; groups that span
    the embed/head boundary keep the layer slice only.
    """
    segs = []
    for lo, hi in schedule.groups:
        # schedule indices: 1 = embed, 2..L+1 = layers, L+2 = head
        start = max(lo - 2, 0)
        stop = min(hi - 1, num_scan_layers)
        if stop > start:
            segs.append((start, stop))
    # Ensure full coverage of the scan axis.
    covered = sum(b - a for a, b in segs)
    if covered != num_scan_layers:
        raise ValueError(f"scan segments {segs} do not cover {num_scan_layers} layers")
    return tuple(segs)
