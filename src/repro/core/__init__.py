"""MG-WFBP core: the paper's contribution as a composable JAX module.

Public surface:
  comm_model  — α–β all-reduce cost models (paper Table II) + TPU ICI presets
  cost_model  — per-layer backward-time model (paper Eq. 18) + hardware presets
  timeline    — WFBP timeline evaluation (paper Eqs. 6–8, 19–21)
  schedule    — Algorithm 1 (MG-WFBP), WFBP/SyncEASGD/fixed-bucket baselines,
                exhaustive exact optimum
  bucketing   — param-pytree <-> schedule-bucket mapping (leaf + stacked
                units) + the per-group wire plan and flat arena layouts
  sync        — the unified bucketed reducer: one all-reduce per schedule
                group inside shard_map, concat | variadic | arena wire
                layouts (see also repro.planning for the Plan artifact /
                policy registry / cost sources)
  profiler    — HLO segment cost extraction + collective-traffic parser
"""

from .comm_model import (
    ALGORITHMS,
    AllReduceModel,
    TPU_V5E as TPU_V5E_ICI,
    TpuInterconnect,
    binary_tree,
    fit_affine,
    paper_cluster_model,
    recursive_doubling,
    recursive_halving_doubling,
    ring,
    tpu_psum_model,
)
from .cost_model import Hardware, LayerCost, NVIDIA_K80, TPU_V5E, lm_layer_costs
from .timeline import TimelineResult, evaluate, gradient_avail_times
from .schedule import (
    Schedule,
    evaluate_schedule,
    fixed_bucket_schedule,
    groups_from_merged_set,
    mg_wfbp_schedule,
    optimal_schedule,
    synceasgd_schedule,
    wfbp_schedule,
)
from .bucketing import (
    ArenaSlot,
    CommUnit,
    GroupArena,
    ParamLayout,
    bucket_assignment,
    group_arenas,
    layer_buckets_for_scan,
    layout_for_stacked_lm,
    layout_from_params,
    stacked_lm_layout,
    tree_get,
    tree_set,
)
from .schedule import dp_optimal_schedule
from .sync import (
    SyncConfig,
    count_expected_allreduces,
    make_gradient_sync,
    wire_entries,
)
from .profiler import CollectiveStats, SegmentCost, parse_collectives, segment_cost

__all__ = [
    "ALGORITHMS",
    "AllReduceModel",
    "TPU_V5E_ICI",
    "TpuInterconnect",
    "binary_tree",
    "fit_affine",
    "paper_cluster_model",
    "recursive_doubling",
    "recursive_halving_doubling",
    "ring",
    "tpu_psum_model",
    "Hardware",
    "LayerCost",
    "NVIDIA_K80",
    "TPU_V5E",
    "lm_layer_costs",
    "TimelineResult",
    "evaluate",
    "gradient_avail_times",
    "Schedule",
    "evaluate_schedule",
    "fixed_bucket_schedule",
    "groups_from_merged_set",
    "mg_wfbp_schedule",
    "optimal_schedule",
    "synceasgd_schedule",
    "wfbp_schedule",
    "ArenaSlot",
    "CommUnit",
    "GroupArena",
    "ParamLayout",
    "bucket_assignment",
    "group_arenas",
    "layer_buckets_for_scan",
    "layout_for_stacked_lm",
    "layout_from_params",
    "stacked_lm_layout",
    "tree_get",
    "tree_set",
    "dp_optimal_schedule",
    "SyncConfig",
    "count_expected_allreduces",
    "make_gradient_sync",
    "wire_entries",
    "CollectiveStats",
    "SegmentCost",
    "parse_collectives",
    "segment_cost",
]
