"""MG-WFBP reproduction: merged-gradient WFBP scheduling for distributed
synchronous SGD, grown into a JAX training-and-serving system.

Subpackages: ``planning`` (Plan artifact, policy registry, cost sources),
``core`` (schedulers, timeline, sync engine), ``launch``, ``runtime``,
``models``, ``kernels``, ``optim``, ``data``, ``checkpoint``, ``serving``.
"""

__version__ = "0.1.0"
